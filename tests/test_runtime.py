"""Distributed-runtime tests: checkpointing, fault tolerance, data
determinism, and (subprocess, 8 fake devices) sharded-step equivalence."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenStream
from repro.train import (
    StragglerMonitor,
    Supervisor,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


# --------------------------------------------------------------------------
# deterministic data pipeline
# --------------------------------------------------------------------------
def test_token_stream_deterministic_and_sharded():
    a = TokenStream(vocab_size=1000, seq_len=16, global_batch=8, seed=3,
                    shard_index=0, shard_count=2)
    b = TokenStream(vocab_size=1000, seq_len=16, global_batch=8, seed=3,
                    shard_index=1, shard_count=2)
    x0 = a.batch_at(7)
    x0_again = a.batch_at(7)
    np.testing.assert_array_equal(x0["tokens"], x0_again["tokens"])
    # different shards produce different data
    assert not np.array_equal(x0["tokens"], b.batch_at(7)["tokens"])
    # skip-ahead: batch at step N does not depend on having drawn 0..N-1
    fresh = TokenStream(vocab_size=1000, seq_len=16, global_batch=8, seed=3,
                        shard_index=0, shard_count=2)
    np.testing.assert_array_equal(fresh.batch_at(7)["tokens"], x0["tokens"])
    assert x0["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(x0["tokens"][:, 1:], x0["labels"][:, :-1])


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
def _tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)),
                   "b": jnp.zeros((8,), jnp.bfloat16)},
        "step": jnp.asarray(3, jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _tiny_state()
    save_checkpoint(str(tmp_path), state, 3)
    assert latest_step(str(tmp_path)) == 3
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_latest(tmp_path):
    state = _tiny_state()
    save_checkpoint(str(tmp_path), state, 3)
    save_checkpoint(str(tmp_path), state, 10)
    assert latest_step(str(tmp_path)) == 10
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 10


def test_checkpoint_digest_verification(tmp_path):
    state = _tiny_state()
    d = save_checkpoint(str(tmp_path), state, 1)
    # corrupt a leaf
    leaf = os.path.join(d, "leaf_0.npy")
    arr = np.load(leaf)
    arr_corrupt = np.asarray(arr).copy()
    arr_corrupt.reshape(-1)[0] += 1
    np.save(leaf, arr_corrupt)
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), state)


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), _tiny_state(), 1)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"just_one": jnp.zeros(3)})


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------
def test_supervisor_restarts_from_checkpoint(tmp_path):
    """A step that crashes twice mid-run must resume from the checkpoint
    and produce the exact same final state as an uninterrupted run."""
    calls = {"n": 0}

    def step_fn_flaky(state, batch):
        calls["n"] += 1
        if calls["n"] in (4, 9):
            raise RuntimeError("injected device failure")
        return {"x": state["x"] + batch}, {}

    def batch_fn(step):
        return jnp.asarray(float(step + 1))

    sup = Supervisor(str(tmp_path), ckpt_every=2, max_restarts=5)
    state, stats = sup.run({"x": jnp.asarray(0.0)}, step_fn_flaky, batch_fn,
                           n_steps=8)
    assert stats["restarts"] == 2
    # uninterrupted reference
    ref = 0.0
    for s in range(8):
        ref += s + 1
    assert float(state["x"]) == ref


def test_supervisor_resumes_across_runs(tmp_path):
    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {}

    batch_fn = lambda step: jnp.asarray(1.0)
    sup = Supervisor(str(tmp_path), ckpt_every=2)
    state, _ = sup.run({"x": jnp.asarray(0.0)}, step_fn, batch_fn, n_steps=4)
    assert float(state["x"]) == 4.0
    # a brand-new supervisor process picks up at the checkpoint
    sup2 = Supervisor(str(tmp_path), ckpt_every=2)
    state2, _ = sup2.run({"x": jnp.asarray(0.0)}, step_fn, batch_fn,
                         n_steps=8)
    assert float(state2["x"]) == 8.0


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=4.0)
    flagged = [mon.observe(1.0 + 0.01 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert mon.observe(5.0)       # 5x latency spike
    assert not mon.observe(1.01)  # recovery


# --------------------------------------------------------------------------
# multi-device (8 fake CPU devices, subprocess so device count is fresh)
# --------------------------------------------------------------------------
_SUBPROC_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, numpy as np
import jax.numpy as jnp
"""


def _run_subprocess(body: str):
    script = _SUBPROC_PRELUDE + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=520,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    """(2 dp x 4 tp) sharded train step == unsharded step (same loss)."""
    out = _run_subprocess("""
    from repro.configs import get_config, smoke_config
    from repro.train import TrainConfig, init_train_state, make_train_step
    from repro.data import TokenStream
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config(get_config("qwen3-0.6b"))
    tcfg = TrainConfig(remat=False)
    mesh = make_host_mesh(dp=2, tp=4)
    state = init_train_state(cfg, tcfg)
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}

    step, jit_step, state_sh = make_train_step(cfg, tcfg, mesh)
    specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    jstep = jit_step(specs)
    state_placed = jax.device_put(state, state_sh)
    new_state, metrics = jstep(state_placed, batch)
    sharded_loss = float(metrics["loss"])

    # unsharded reference (fresh identical state; step was donated)
    state2 = init_train_state(cfg, tcfg)
    from repro.nn.transformer import loss_fn
    ref_loss = float(loss_fn(cfg)(state2["params"], batch=batch))
    print("LOSSES", sharded_loss, ref_loss)
    assert abs(sharded_loss - ref_loss) < 0.05, (sharded_loss, ref_loss)
    assert int(new_state["step"]) == 1
    """)
    assert "LOSSES" in out


def test_moe_shard_map_matches_local():
    """Expert-parallel shard_map MoE == single-device reference."""
    out = _run_subprocess("""
    from repro.configs import get_config, smoke_config
    from repro.nn.moe import moe_block
    from repro.nn.sharding import use_mesh
    from repro.nn.transformer import init_params
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config(get_config("qwen3-moe-30b-a3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda a: a[0], params["blocks"])
    moe_params = {"router": p0["router"], "w_in": p0["moe_w_in"],
                  "w_out": p0["moe_w_out"]}
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                          dtype=jnp.bfloat16)

    y_ref, aux_ref = moe_block(moe_params, x, cfg)          # no mesh
    mesh = make_host_mesh(dp=2, tp=4)
    with use_mesh(mesh):
        y_sh, aux_sh = jax.jit(lambda p, x: moe_block(p, x, cfg))(moe_params, x)
    err = float(jnp.abs(y_ref.astype(jnp.float32) - y_sh.astype(jnp.float32)).max())
    print("MOE_ERR", err, float(aux_ref), float(aux_sh))
    assert err < 0.1, err
    """)
    assert "MOE_ERR" in out


def test_elastic_remesh_restore():
    """Checkpoint saved from a (2,4) mesh restores onto (4,2) and (1,1)."""
    out = _run_subprocess("""
    import tempfile
    from repro.configs import get_config, smoke_config
    from repro.train import (TrainConfig, init_train_state, save_checkpoint,
                             restore_checkpoint, train_state_shardings)
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config(get_config("qwen3-0.6b"))
    tcfg = TrainConfig()
    mesh_a = make_host_mesh(dp=2, tp=4)
    state = jax.device_put(init_train_state(cfg, tcfg),
                           train_state_shardings(cfg, tcfg, mesh_a))
    d = tempfile.mkdtemp()
    save_checkpoint(d, state, 5)

    mesh_b = make_host_mesh(dp=4, tp=2)
    sh_b = train_state_shardings(cfg, tcfg, mesh_b)
    restored, step = restore_checkpoint(d, jax.eval_shape(lambda: init_train_state(cfg, tcfg)), shardings=sh_b)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("REMESH_OK")
    """)
    assert "REMESH_OK" in out


def test_compressed_gradient_step_converges_like_uncompressed():
    """int8 EF compression: first-step loss equal, params move similarly."""
    out = _run_subprocess("""
    from repro.configs import get_config, smoke_config
    from repro.train import TrainConfig, init_train_state, make_train_step
    from repro.data import TokenStream
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config(get_config("qwen3-0.6b"))
    mesh = make_host_mesh(dp=4, tp=2)
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}

    results = {}
    for compress in (False, True):
        tcfg = TrainConfig(remat=False, grad_compress=compress)
        step, jit_step, state_sh = make_train_step(cfg, tcfg, mesh)
        state = jax.device_put(init_train_state(cfg, tcfg), state_sh)
        jstep = jit_step(specs)
        losses = []
        for i in range(4):
            b = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
            state, m = jstep(state, b)
            losses.append(float(m["loss"]))
        results[compress] = losses
    print("LOSSES", results[False], results[True])
    # same first loss (compression acts on grads, not forward)
    assert abs(results[False][0] - results[True][0]) < 1e-3
    # both decreasing
    assert results[True][-1] < results[True][0]
    assert abs(results[True][-1] - results[False][-1]) < 0.5
    """)
    assert "LOSSES" in out
