"""Numerical equivalence tests for the model-substrate primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.nn.attention import mha
from repro.nn.rglru import causal_conv1d, rg_lru, rg_lru_step
from repro.nn.ssm import wkv_chunked, wkv_decode_step, wkv_scan_ref


# --------------------------------------------------------------------------
# RWKV6 chunked GLA
# --------------------------------------------------------------------------
@given(
    t=st.integers(min_value=1, max_value=70),
    seed=st.integers(min_value=0, max_value=20),
    strong=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_wkv_chunked_equals_scan(t, seed, strong):
    rng = np.random.default_rng(seed)
    b, h, n = 2, 2, 8
    q, k, v = (jnp.asarray(rng.normal(size=(b, t, h, n)), jnp.float32)
               for _ in range(3))
    hi = 1.2 if strong else -1.0
    log_w = jnp.asarray(-np.exp(rng.uniform(-4, hi, size=(b, t, h, n))),
                        jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    y_ref, s_ref = wkv_scan_ref(q, k, v, log_w, u)
    y, s = wkv_chunked(q, k, v, log_w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-4)


def test_wkv_decode_chain_equals_scan():
    rng = np.random.default_rng(0)
    b, t, h, n = 1, 12, 2, 8
    q, k, v = (jnp.asarray(rng.normal(size=(b, t, h, n)), jnp.float32)
               for _ in range(3))
    log_w = jnp.asarray(-np.exp(rng.uniform(-3, 0, size=(b, t, h, n))),
                        jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    y_ref, _ = wkv_scan_ref(q, k, v, log_w, u)
    s = jnp.zeros((b, h, n, n))
    ys = []
    for i in range(t):
        y, s = wkv_decode_step(q[:, i], k[:, i], v[:, i], log_w[:, i], u, s)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), rtol=1e-5, atol=1e-5)


def test_wkv_state_handoff_mid_sequence():
    """chunked(T) == chunked(T/2) -> carry state -> chunked(T/2)."""
    rng = np.random.default_rng(3)
    b, t, h, n = 2, 64, 2, 8
    q, k, v = (jnp.asarray(rng.normal(size=(b, t, h, n)), jnp.float32)
               for _ in range(3))
    log_w = jnp.asarray(-np.exp(rng.uniform(-3, 0.5, size=(b, t, h, n))),
                        jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, n)), jnp.float32)
    y_full, s_full = wkv_chunked(q, k, v, log_w, u, chunk=16)
    half = t // 2
    y1, s1 = wkv_chunked(q[:, :half], k[:, :half], v[:, :half],
                         log_w[:, :half], u, chunk=16)
    y2, s2 = wkv_chunked(q[:, half:], k[:, half:], v[:, half:],
                         log_w[:, half:], u, chunk=16, state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------
def _lru_params(d, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w_a": jnp.asarray(rng.normal(size=(d, d)) * 0.2, jnp.float32),
        "w_x": jnp.asarray(rng.normal(size=(d, d)) * 0.2, jnp.float32),
        "lam": jnp.asarray(rng.uniform(0.5, 2.0, size=(d,)), jnp.float32),
    }


def test_rg_lru_scan_equals_steps():
    d, b, t = 6, 2, 20
    params = _lru_params(d)
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.normal(size=(b, t, d)), jnp.float32)
    h_scan, last = rg_lru(params, u)
    h = jnp.zeros((b, d))
    outs = []
    for i in range(t):
        y, h = rg_lru_step(params, u[:, i], h)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(h_scan), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(last),
                               rtol=1e-5, atol=1e-5)


def test_rg_lru_state_carry():
    d, b, t = 4, 1, 16
    params = _lru_params(d, 2)
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.normal(size=(b, t, d)), jnp.float32)
    h_full, last_full = rg_lru(params, u)
    h1, s1 = rg_lru(params, u[:, :8])
    h2, s2 = rg_lru(params, u[:, 8:], h_prev=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(h_full), rtol=1e-5, atol=1e-5)


def test_causal_conv1d_matches_direct():
    rng = np.random.default_rng(0)
    b, t, d, kw = 2, 10, 3, 4
    w = jnp.asarray(rng.normal(size=(kw, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, t, d)), jnp.float32)
    out, state = causal_conv1d(w, x)
    # direct: y[t] = sum_k w[k] x[t - (K-1) + k]
    xp = np.concatenate([np.zeros((b, kw - 1, d), np.float32),
                         np.asarray(x)], axis=1)
    want = sum(np.asarray(w)[k][None, None] * xp[:, k:k + t]
               for k in range(kw))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(state), xp[:, -(kw - 1):])


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def _naive_attention(q, k, v, causal, window, q_offset=0):
    b, tq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qr = q.reshape(b, tq, kvh, g, dh)
    s = np.einsum("bqkgd,btkd->bkgqt", np.asarray(qr, np.float32),
                  np.asarray(k, np.float32)) / np.sqrt(dh)
    pos_q = np.arange(tq) + q_offset
    pos_k = np.arange(k.shape[1])
    mask = np.ones((tq, k.shape[1]), bool)
    if causal:
        mask &= pos_q[:, None] >= pos_k[None]
    if window:
        mask &= (pos_q[:, None] - pos_k[None]) < window
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    out = np.einsum("bkgqt,btkd->bqkgd", p, np.asarray(v, np.float32))
    return out.reshape(b, tq, h, dh)


@pytest.mark.parametrize("tq,chunk_q,causal,window", [
    (16, 512, True, None),     # single chunk
    (64, 16, True, None),      # chunked causal
    (48, 16, True, None),      # ragged chunking (pad path)
    (64, 16, False, None),     # encoder
    (64, 16, True, 8),         # local window
])
def test_mha_matches_naive(tq, chunk_q, causal, window):
    rng = np.random.default_rng(tq + chunk_q)
    b, h, kvh, dh = 2, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, tq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, tq, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, tq, kvh, dh)), jnp.float32)
    out = mha(q, k, v, causal=causal, window=window, chunk_q=chunk_q)
    want = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# LUT activation integration
# --------------------------------------------------------------------------
def test_lut_activation_build_and_apply():
    from repro.nn.lut_act import build_lut_activation
    from repro.nn.mlp import lut_act_jnp

    calib = np.random.default_rng(0).normal(size=20000) * 2
    lut = build_lut_activation("silu", calib, w_in=9, w_out=9,
                               x_lo=-6.0, x_hi=6.0)
    assert 0.0 < lut.dontcare_frac < 1.0
    tables = lut.tables_for_model()
    x = jnp.asarray(np.clip(np.random.default_rng(1).normal(size=512) * 2,
                            -5.9, 5.9), jnp.float32)
    y = lut_act_jnp(x, tables["arrays"], **tables["meta"])
    ref = jax.nn.silu(x)
    step = 12.0 / 511 + 12.0 / 511
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2 * step + 1e-3)
