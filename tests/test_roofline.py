"""Tests for the loop-aware HLO cost extraction (roofline engine)."""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.roofline.hlo_costs import analyze_hlo, parse_hlo
from repro.roofline.analysis import RooflineTerms, model_flops_per_step


SYNTH_HLO = """
HloModule jit_f, is_scheduled=true

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %w = f32[128,128]{1,0} constant({...})
  %y = f32[8,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128]{1,0} all-reduce(%y), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[8,128]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,128]) -> f32[8,128] {
  %x = f32[8,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%c0, %x)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_hlo_structure():
    comps, entry = parse_hlo(SYNTH_HLO)
    assert entry == "main"
    assert set(comps) == {"add", "body", "cond", "main"}
    body = comps["body"]
    opcodes = [op.opcode for op in body.ops]
    assert "dot" in opcodes and "all-reduce" in opcodes


def test_loop_multipliers_and_costs():
    c = analyze_hlo(SYNTH_HLO)
    # dot: 2 * (8*128 out) * 128 contract * 12 trips
    assert c.flops == 2 * 8 * 128 * 128 * 12
    # all-reduce: 8*128*4B * 12 trips * ring factor 2
    assert c.per_op_coll["all-reduce"] == 8 * 128 * 4 * 12 * 2
    assert c.trip_counts.get("body") == 12
    assert c.hbm_bytes > 0


def test_comment_in_tuple_types_is_stripped():
    hlo = SYNTH_HLO.replace("(s32[], f32[8,128])",
                            "(s32[], /*index=1*/f32[8,128])")
    c = analyze_hlo(hlo)
    assert c.flops == 2 * 8 * 128 * 128 * 12


def test_roofline_terms_dominance():
    t = RooflineTerms(flops=197e12, hbm_bytes=1e9, coll_bytes=0,
                      per_op_coll={})
    assert t.compute_s == 1.0
    assert t.dominant == "compute"
    t2 = RooflineTerms(flops=1e9, hbm_bytes=819e9 * 2, coll_bytes=0,
                       per_op_coll={})
    assert t2.dominant == "memory"


def test_model_flops_dense_vs_moe():
    from repro.configs import get_config
    dense = get_config("qwen3-0.6b")
    moe = get_config("qwen3-moe-30b-a3b")
    f_d = model_flops_per_step(dense, 256, 4096, "train")
    assert f_d == 6.0 * dense.n_params() * 256 * 4096
    # MoE uses active params only
    f_m = model_flops_per_step(moe, 256, 4096, "train")
    assert f_m < 6.0 * moe.n_params() * 256 * 4096


def test_real_compile_roundtrip():
    """End-to-end on a real (8 fake devices) compiled module."""
    script = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.roofline.hlo_costs import analyze_hlo
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    def f(x, w):
        def body(h, wl):
            h = jax.lax.with_sharding_constraint(
                jnp.tanh(h @ wl), NamedSharding(mesh, P("data", "model")))
            h = jax.lax.with_sharding_constraint(
                h @ wl.T, NamedSharding(mesh, P("data", None)))
            return h, ()
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h)

    xs = jax.ShapeDtypeStruct((64, 256), np.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), np.float32)
    comp = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P("data", None)),
        NamedSharding(mesh, P(None, None, "model")))).lower(xs, ws).compile()
    c = analyze_hlo(comp.as_text())
    expect = 2 * 2 * 32 * 64 * 256 * 7  # 2 dots, local shapes, 7 trips
    assert abs(c.flops - expect) / expect < 0.01, (c.flops, expect)
    assert c.per_op_coll.get("all-reduce", 0) > 0
    print("ROOFLINE_OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=520,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "ROOFLINE_OK" in proc.stdout
