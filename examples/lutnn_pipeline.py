"""End-to-end paper pipeline on the JSC-2L model (paper Fig. 2).

train LUT-NN -> extract truth tables -> mark don't cares from the training
set -> compress (baseline / CompressedLUT / ReducedLUT / random control)
-> evaluate accuracy on the reconstructed tables -> emit Verilog.

Run:  PYTHONPATH=src python examples/lutnn_pipeline.py
"""
import numpy as np

from repro.core import (
    CompressConfig,
    compress_network_report,
    network_to_verilog,
    rom_baseline_cost,
)
from repro.data import make_jsc
from repro.lutnn import (
    extract_tables,
    mark_observed,
    table_accuracy,
    train_lutnn,
)
from repro.lutnn.extract import network_table_specs, specs_to_tables
from repro.lutnn.model import paper_model


def main() -> None:
    print("1. training JSC-2L (paper Table 1: 32+5 neurons, beta=4, F=3)")
    cfg = paper_model("jsc-2l")
    xtr, ytr, xte, yte = make_jsc(12000, 3000)
    params, conn, metrics = train_lutnn(cfg, xtr, ytr, xte, yte, epochs=12)
    print(f"   train acc {metrics['train_acc']:.4f}  "
          f"test acc {metrics['test_acc']:.4f}")

    print("2. extracting truth tables + marking don't cares")
    tables = extract_tables(params, cfg)
    observed = mark_observed(tables, conn, cfg, xtr)
    dc = [f"{1 - o.mean():.2f}" for o in observed]
    print(f"   don't-care fraction per layer: {dc}")

    print("3. compressing network (37 L-LUTs, engine workers=2)")
    specs_ac = network_table_specs(tables, None, cfg)
    specs_dc = network_table_specs(tables, observed, cfg)
    baseline = sum(rom_baseline_cost(s) for s in specs_ac)
    mc = CompressConfig(exiguity=None, m_candidates=(8, 16, 32, 64),
                        lb_candidates=(0, 1, 2))
    rc = CompressConfig(exiguity=250, m_candidates=(8, 16, 32, 64),
                        lb_candidates=(0, 1, 2))
    rep_c = compress_network_report(specs_ac, mc, workers=2)
    rep_r = compress_network_report(specs_dc, rc, workers=2)
    plans_r = rep_r.plans
    cost_c, cost_r = rep_c.total_cost, rep_r.total_cost
    print(f"   CompressedLUT: {rep_c.summary()}")
    print(f"   ReducedLUT:    {rep_r.summary()}")
    print(f"   baseline {baseline} | CompressedLUT {cost_c} "
          f"({1 - cost_c / baseline:.0%} saved) | ReducedLUT {cost_r} "
          f"({1 - cost_r / baseline:.0%} saved, "
          f"{1 - cost_r / cost_c:.0%} vs CompressedLUT)")

    print("4. accuracy on reconstructed tables")
    tab_r = specs_to_tables([p.reconstruct() for p in plans_r], cfg)
    acc_before = table_accuracy(tables, conn, cfg, xte, yte)
    acc_after = table_accuracy(tab_r, conn, cfg, xte, yte)
    tr_before = table_accuracy(tables, conn, cfg, xtr, ytr)
    tr_after = table_accuracy(tab_r, conn, cfg, xtr, ytr)
    print(f"   test acc {acc_before:.4f} -> {acc_after:.4f}  "
          f"train acc {tr_before:.4f} -> {tr_after:.4f} (must be equal)")
    assert tr_before == tr_after

    print("5. emitting Verilog")
    v = network_to_verilog(plans_r)
    with open("/tmp/jsc2l_reducedlut.v", "w") as f:
        f.write(v)
    print(f"   wrote /tmp/jsc2l_reducedlut.v ({len(v.splitlines())} lines)")


if __name__ == "__main__":
    main()
