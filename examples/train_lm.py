"""End-to-end LM training driver on the distributed runtime.

Trains a reduced qwen3-family decoder for a few hundred steps on the
deterministic token pipeline, under the fault-tolerant Supervisor with
periodic checkpoints — the same step builder the 512-chip dry-run lowers,
on a host mesh.  ``--big`` uses a ~100M-parameter config (slow on CPU).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--big]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.data import TokenStream
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig, warmup_cosine_schedule
from repro.train import (
    Supervisor,
    TrainConfig,
    init_train_state,
    make_train_step,
    train_state_shardings,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true",
                    help="~100M params (CPU-slow)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(get_config("qwen3-0.6b"))
    if args.big:
        cfg = dataclasses.replace(
            cfg, name="qwen3-100m", n_layers=6, d_model=512, n_heads=8,
            n_kv_heads=4, d_head=64, d_ff=2048, vocab_size=32768,
        )
    print(f"model: {cfg.name}  ~{cfg.n_params() / 1e6:.1f}M params")

    tcfg = TrainConfig(
        optimizer=AdamWConfig(
            lr=warmup_cosine_schedule(3e-3, args.steps // 10, args.steps),
            weight_decay=0.01,
        ),
        remat=False,
        microbatch=None,
    )
    mesh = make_host_mesh(dp=1, tp=1)
    stream = TokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)

    step, jit_step, state_sh = make_train_step(cfg, tcfg, mesh)
    specs = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype)
        for k, v in stream.batch_at(0).items()
    }
    jstep = jit_step(specs)
    state = jax.device_put(init_train_state(cfg, tcfg),
                           train_state_shardings(cfg, tcfg, mesh))

    losses = []

    def on_metrics(s, m):
        losses.append(float(m["loss"]))
        if s % 20 == 0 or s == args.steps - 1:
            print(f"  step {s:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}  |g| {float(m['grad_norm']):.3f}")

    def step_fn(state, batch):
        return jstep(state, {k: jnp.asarray(v) for k, v in batch.items()})

    ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_")
    sup = Supervisor(ckpt_dir, ckpt_every=50)
    state, stats = sup.run(state, step_fn, stream.batch_at, args.steps,
                           on_metrics=on_metrics)
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ckpts in {ckpt_dir}, stragglers={stats['stragglers']})")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
