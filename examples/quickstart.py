"""Quickstart: compress one lookup table with ReducedLUT.

Builds a random-looking 12-bit table with don't cares, runs the paper's
flow (CompressedLUT baseline vs ReducedLUT at several exiguity levels),
prints the analytical P-LUT costs, emits Verilog, and evaluates the
decomposed table with the Pallas kernel (interpret mode on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    CompressConfig,
    TableSpec,
    compress_table,
    plan_to_verilog,
    rom_baseline_cost,
    verify_care_exact,
)
from repro.kernels import PlanArrays, lut_reconstruct


def main() -> None:
    spec = TableSpec.random(
        w_in=12, w_out=8, dontcare_frac=0.6, seed=7, smooth=True,
        name="quickstart",
    )
    print(f"table: 2^{spec.w_in} x {spec.w_out}b, "
          f"{spec.n_dontcare}/{spec.size} don't cares")
    print(f"plain tabulation:      {rom_baseline_cost(spec):5d} P-LUTs")

    compressed = compress_table(spec, CompressConfig(exiguity=None))
    print(f"CompressedLUT:         {compressed.plut_cost():5d} P-LUTs "
          f"(no don't cares)")

    for ex in (20, 250):
        plan = compress_table(spec, CompressConfig(exiguity=ex))
        assert verify_care_exact(spec, plan), "care entries must be exact"
        print(f"ReducedLUT (ex={ex:3d}):  {plan.plut_cost():5d} P-LUTs "
              f"({plan.kind})")

    # Verilog emission (paper toolflow output)
    verilog = plan_to_verilog(plan)
    print(f"\nVerilog: {len(verilog.splitlines())} lines "
          f"(module llut_{spec.name})")

    # evaluate through the Pallas kernel
    pa = PlanArrays.from_plan(plan)
    xs = np.random.default_rng(0).integers(0, spec.size, 1024)
    out = lut_reconstruct(jnp.asarray(xs), pa)
    want = plan.reconstruct()[xs]
    assert np.array_equal(np.asarray(out), want)
    care = spec.care_mask()[xs]
    exact = np.asarray(out)[care] == spec.values[xs][care]
    print(f"Pallas kernel eval: {xs.size} lookups, "
          f"care-exact={exact.all()}")


if __name__ == "__main__":
    main()
