"""Serve a small transformer with ReducedLUT-compressed activations.

The paper's technique as a serving feature: each layer's MLP nonlinearity
is replaced by a quantize -> compressed-table -> dequantize evaluation
whose table was compressed with don't cares mined from that *site's own*
observed input patterns (repro.calib streaming capture — the per-site
analogue of paper SS4.1's unobserved-training-pattern rule).  Batched
requests run through prefill + decode; outputs are compared against the
exact-activation model and the gather/pallas backends are asserted
bit-identical.

Run:  PYTHONPATH=src python examples/serve_lut_transformer.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.calib import capture_calibration, synthetic_batches
from repro.configs import get_config, smoke_config
from repro.nn import init_params
from repro.nn.transformer import decoder_forward
from repro.nn.layers import logits_projection
from repro.serve import (
    build_serving_plans,
    decode_step,
    prefill,
    verify_backend_equivalence,
)

B, T, NEW = 4, 48, 8


def main() -> None:
    cfg = smoke_config(get_config("phi4-mini-3.8b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, T)), jnp.int32)

    # 1. per-site calibration: stream batches through the exact model and
    #    record every layer's observed pre-activation bins
    print("1. capturing per-site activation patterns on sample traffic")
    batches = synthetic_batches(cfg, steps=4, batch_size=B, seq_len=T,
                                seed=1)
    calib = capture_calibration(params, cfg, batches)
    print(f"   {calib.summary()}")

    # 2. compress every (layer, site) table with its own don't cares
    print("2. building per-site ReducedLUT serving plans")
    plans = build_serving_plans(cfg, calib)
    rep = plans.report
    print(f"   {plans.summary()}")
    print(f"   dedupe: {rep.n_unique} unique tables / {len(rep.tables)} "
          f"sites (rate {rep.dedup_rate:.0%} — per-site masks keep "
          f"layers distinct)")

    lut_tables = plans.tables_for_model()
    cfg_lut = plans.patched_config(cfg)

    # 3. exact vs LUT-activation forward
    print("3. comparing logits (exact vs per-site LUT activations)")
    x_exact, _, _ = decoder_forward(params, cfg, tokens)
    x_lut, _, _ = decoder_forward(params, cfg_lut, tokens,
                                  lut_tables=lut_tables)
    lg_e = logits_projection(x_exact, params["lm_head"]).astype(jnp.float32)
    lg_l = logits_projection(x_lut, params["lm_head"]).astype(jnp.float32)
    agree = float(jnp.mean(jnp.argmax(lg_e, -1) == jnp.argmax(lg_l, -1)))
    print(f"   argmax agreement over {B}x{T} positions: {agree:.3f}")

    # 4. the fused Pallas path must bit-match the gather reference
    print("4. verifying gather/pallas backend bit-equivalence")
    verify_backend_equivalence(cfg, params, plans,
                               np.asarray(tokens)[:, :8], 3)
    print("   token-for-token identical")

    # 5. batched serving: prefill + greedy decode with the LUT tables
    print(f"5. serving {B} requests: prefill {T} tokens + {NEW} decode "
          f"steps")
    logits, cache = jax.jit(
        lambda p, b: prefill(p, cfg_lut, b, max_seq=T + NEW,
                             lut_tables=lut_tables))(
            params, {"tokens": tokens})
    step = jax.jit(lambda p, c, t, pos: decode_step(
        p, cfg_lut, c, t, pos, lut_tables=lut_tables))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for i in range(NEW):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, cache, tok, jnp.asarray(T + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    print(f"   decoded tokens (req 0): {[int(t[0]) for t in out_tokens]}")
    print("done.")


if __name__ == "__main__":
    main()
