"""Serve a small transformer with ReducedLUT-compressed activations.

The paper's technique as a serving feature: the MLP nonlinearity is
replaced by a quantize -> compressed-table -> dequantize evaluation whose
table was compressed with don't cares mined from calibration batches.
Batched requests run through prefill + decode; outputs are compared
against the exact-activation model.

Run:  PYTHONPATH=src python examples/serve_lut_transformer.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import rom_baseline_cost
from repro.core.table import TableSpec
from repro.nn import init_params
from repro.nn.lut_act import build_lut_activation
from repro.nn.transformer import decoder_forward
from repro.nn.layers import logits_projection
from repro.serve import decode_step, prefill

B, T, NEW = 4, 48, 8


def main() -> None:
    cfg = smoke_config(get_config("phi4-mini-3.8b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, T)), jnp.int32)

    # 1. calibration: collect pre-activation values from a few batches
    print("1. calibrating activation range on sample traffic")
    from repro.nn.mlp import mlp_block  # noqa: F401  (same path the model uses)
    acts = []

    def probe(p, toks):
        x, _, _ = decoder_forward(p, cfg, toks)
        return x

    # use gate pre-activations ~ N(0, 1): sample hidden stream directly
    h = probe(params, tokens)
    acts.append(np.asarray(h.astype(jnp.float32)).reshape(-1))
    calib = np.concatenate(acts)

    # 2. build + compress the activation table with don't cares
    print("2. building ReducedLUT-compressed SiLU table")
    lut = build_lut_activation("silu", calib, w_in=10, w_out=10,
                               x_lo=-8.0, x_hi=8.0, exiguity=250)
    plain = rom_baseline_cost(TableSpec(
        lut.plan.reconstruct(), lut.w_in, lut.w_out))
    print(f"   don't-care bins: {lut.dontcare_frac:.1%}  "
          f"P-LUTs: plain {plain} -> compressed {lut.plan.plut_cost()}")

    lut_tables = lut.tables_for_model()
    cfg_lut = dataclasses.replace(cfg, lut_activation=True)

    # 3. exact vs LUT-activation forward
    print("3. comparing logits (exact vs LUT activation)")
    x_exact, _, _ = decoder_forward(params, cfg, tokens)
    x_lut, _, _ = decoder_forward(params, cfg_lut, tokens,
                                  lut_tables=lut_tables)
    lg_e = logits_projection(x_exact, params["lm_head"]).astype(jnp.float32)
    lg_l = logits_projection(x_lut, params["lm_head"]).astype(jnp.float32)
    agree = float(jnp.mean(jnp.argmax(lg_e, -1) == jnp.argmax(lg_l, -1)))
    print(f"   argmax agreement over {B}x{T} positions: {agree:.3f}")

    # 4. batched serving: prefill + greedy decode
    print(f"4. serving {B} requests: prefill {T} tokens + {NEW} decode steps")
    logits, cache = jax.jit(
        lambda p, b: prefill(p, cfg, b, max_seq=T + NEW))(
            params, {"tokens": tokens})
    step = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for i in range(NEW):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, cache, tok, jnp.asarray(T + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    print(f"   decoded tokens (req 0): {[int(t[0]) for t in out_tokens]}")
    print("done.")


if __name__ == "__main__":
    main()
